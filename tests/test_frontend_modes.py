"""Reconfigurable frontend modes (DESIGN.md §13): conv-in-pixel and the
ADC-less sign readout, plus the default-mode bitwise guarantee.

Contracts pinned here:

* the default patch-bank + ADC epilogue is BITWISE unchanged by the mode
  refactor (``readout="adc"`` explicit == default call, features and
  event ledgers);
* each new mode has a pure-jnp oracle and the kernels match it exactly
  (interpret mode on CPU);
* each mode emits the correct :class:`EventCounts` — sign readout swaps
  ``adc_conversions`` for ``sign_comparisons``; conv prices DAC
  reprogramming only when the kernel bank actually cycles per frame;
* the governor's sign tier slots BELOW the whole k ladder, engages only
  when the budget cannot cover the finest tier's floor allocation, and
  switches readouts with ZERO recompiles (``n_traces == 1``);
* the sign wire is a real wire format: bool payload, its own
  (scale, zero) affine, cache dtype discipline, embed-side bypass of the
  w8a8 kernel.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core as c
from repro.core import adc as adc_mod
from repro.core import power as power_mod
from repro.core import projection as proj
from repro.core import pwm as pwm_mod
from repro.core.frontend import (
    FrontendConfig,
    apply_frontend,
    dequantize_features,
)
from repro.core.projection import ConvSpec, PatchSpec, extract_patches
from repro.core.temporal import TemporalSpec, init_feature_cache
from repro.kernels import ops, ref
from repro.models.vit import ViTConfig, init_vit, vit_forward_compact
from repro.serve.engine import SaccadeEngine
from repro.serve import governor as gov_mod
from repro.serve.governor import GovernorSpec

KEY = jax.random.PRNGKey(0)
FRAME_HZ = 30.0


def _fcfg(**kw):
    base = dict(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
        active_fraction=0.25,
    )
    base.update(kw)
    return FrontendConfig(**base)


def _vcfg(fcfg, **kw):
    base = dict(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)
    base.update(kw)
    return ViTConfig(**base)


# ---------------------------------------------------------------------------
# sign readout: kernel epilogue vs oracle, default-mode bitwise guarantee
# ---------------------------------------------------------------------------

class TestSignReadoutKernel:
    spec = PatchSpec(patch_h=8, patch_w=8, n_vectors=24)

    def _data(self, n_patches=9, batch=2):
        patches = jax.random.uniform(KEY, (batch, n_patches, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (24, 64)) * 3.0
        return patches, w

    def test_default_readout_is_bitwise_unchanged(self):
        """The tentpole's no-regression clause: the mode-selectable
        epilogue with readout='adc' (the default) lowers to the exact
        pre-refactor pipeline — explicit and default calls are bitwise
        equal on every wire."""
        patches, w = self._data()
        adc = adc_mod.ADCSpec(bits=8)
        bias = jax.random.normal(jax.random.PRNGKey(2), (24,)) * 0.1
        for kw in (dict(), dict(adc=adc, bias=bias),
                   dict(adc=adc, bias=bias, codes=True)):
            base = ops.ip2_project(patches, w, self.spec, interpret=True, **kw)
            expl = ops.ip2_project(patches, w, self.spec, readout="adc",
                                   interpret=True, **kw)
            np.testing.assert_array_equal(np.asarray(base), np.asarray(expl))
            assert base.dtype == expl.dtype

    def test_sign_dense_matches_oracle(self):
        patches, w = self._data()
        got = ops.ip2_project(patches, w, self.spec, readout="sign",
                              interpret=True)
        assert got.dtype == jnp.bool_
        w_q, _ = pwm_mod.quantize_weights(w, self.spec.quant)
        params = ops.kernel_params_from_spec(self.spec, readout="sign")
        want = ref.ip2_project_ref(
            patches.reshape(-1, 64), w_q.T, jnp.zeros((24,)), params)
        assert want.dtype == jnp.int8          # kernel-grid {0,1}
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want.reshape(2, 9, 24).astype(bool)))

    def test_sign_bit_is_comparator_of_analog_output(self):
        """The sign epilogue IS the comparator: bit == (Out_v >= V_R) of
        the same analog pipeline the float readout sees."""
        patches, w = self._data()
        bits = ops.ip2_project(patches, w, self.spec, readout="sign",
                               interpret=True)
        out_v = proj.analog_project_patches(patches, w, self.spec)
        want = adc_mod.sign_encode(out_v, self.spec.summer.v_ref)
        np.testing.assert_array_equal(np.asarray(bits), np.asarray(want))

    def test_sign_sparse_matches_dense_gather(self):
        patches, w = self._data()
        idx = jnp.array([[0, 8, 4], [7, 1, 2]], jnp.int32)
        dense = ops.ip2_project(patches, w, self.spec, readout="sign",
                                interpret=True)
        sparse = ops.ip2_project_sparse(patches, w, idx, self.spec,
                                        readout="sign", interpret=True)
        assert sparse.dtype == jnp.bool_
        np.testing.assert_array_equal(
            np.asarray(sparse),
            np.asarray(jnp.take_along_axis(dense, idx[..., None], axis=-2)))
        # ragged entry: shed rows come back as bit 0
        ragged = ops.ip2_project_sparse(
            patches, w, idx, self.spec, readout="sign",
            row_counts=jnp.array([2, 3], jnp.int32), interpret=True)
        np.testing.assert_array_equal(np.asarray(ragged[0, :2]),
                                      np.asarray(sparse[0, :2]))
        assert not np.asarray(ragged[0, 2]).any()

    def test_sign_dequant_affine(self):
        """dequantize(bit, *sign_scale_zero(bias)) == ±v_mag + bias — the
        sign wire reuses the ONE dequant site unchanged (§9/§13)."""
        bias = jnp.float32(0.03)
        scale, zero = adc_mod.sign_scale_zero(bias)
        bits = jnp.array([True, False])
        got = adc_mod.dequantize(bits, scale, zero)
        np.testing.assert_allclose(
            np.asarray(got),
            [adc_mod.SIGN_V_MAG + 0.03, -adc_mod.SIGN_V_MAG + 0.03],
            rtol=1e-6)

    def test_sign_code_points_degrade_like_the_comparator(self):
        """The engine's data-only degradation (already-converted int8
        codes -> two reconstruction points) agrees with the comparator on
        every code of the grid, and dequantizes to the sign affine's
        reconstruction levels through the CODE wire's own affine."""
        spec = adc_mod.ADCSpec(bits=8)
        v_ref, bias = 0.25, 0.05
        c_thresh, c_pos, c_neg = adc_mod.sign_code_points(v_ref, spec)
        out_v = jnp.linspace(spec.v_min, spec.v_max, 1001)
        wire = adc_mod.digital_codes(out_v, v_ref, bias, spec)
        got_bit = np.asarray(wire.codes) >= c_thresh
        want_bit = np.asarray(adc_mod.sign_encode(out_v, v_ref))
        # thresholding the converted code agrees with the real comparator
        # everywhere except (at most) within half an LSB of the boundary —
        # the code grid cannot resolve finer than that
        disagree = got_bit != want_bit
        if disagree.any():
            assert np.abs(np.asarray(out_v)[disagree] - v_ref).max() \
                <= spec.lsb
        # degraded codes land on the ±v_mag reconstruction points (within
        # one LSB — the sign levels are snapped onto the code grid), for
        # ANY bias: the points are bias-independent, the affine carries it
        for b in (0.0, bias):
            scale, zero = adc_mod.readout_scale_zero(v_ref, b, spec)
            recon = np.asarray(adc_mod.dequantize(
                jnp.array([c_pos, c_neg], jnp.int8), scale, zero))
            lvl = np.array([adc_mod.SIGN_V_MAG + b, -adc_mod.SIGN_V_MAG + b])
            assert np.abs(recon - lvl).max() <= spec.lsb

    def test_sign_rejects_code_wire(self):
        patches, w = self._data()
        with pytest.raises(ValueError, match="sign"):
            ops.ip2_project(patches, w, self.spec, adc=adc_mod.ADCSpec(),
                            codes=True, readout="sign", interpret=True)
        with pytest.raises(ValueError, match="readout"):
            ops.ip2_project(patches, w, self.spec, readout="bogus",
                            interpret=True)


# ---------------------------------------------------------------------------
# conv-in-pixel mode
# ---------------------------------------------------------------------------

class TestConvInPixel:
    def _frame(self, h=32, w=32, batch=2):
        return jax.random.uniform(KEY, (batch, h, w))

    def test_extract_windows_nonoverlapping_is_patch_tiling(self):
        frame = self._frame()
        np.testing.assert_array_equal(
            np.asarray(proj.extract_windows(frame, 8, 8)),
            np.asarray(extract_patches(frame, 8, 8)))

    def test_conv_spec_geometry(self):
        conv = ConvSpec(kernel=8, stride=4, n_channels=16)
        assert conv.out_grid(32, 32) == (7, 7)
        ps = conv.patch_spec()
        assert ps.pixels_per_patch == 64 and ps.n_vectors == 16
        with pytest.raises(ValueError, match="not covered"):
            ConvSpec(kernel=8, stride=5, n_channels=16).out_grid(32, 32)
        with pytest.raises(ValueError, match="stride"):
            ConvSpec(kernel=8, stride=0, n_channels=16)

    @pytest.mark.parametrize("kernel,stride", [(8, 8), (8, 4), (16, 8)])
    def test_conv_matches_python_loop_oracle(self, kernel, stride):
        """ops.ip2_conv (im2col gather + projection kernel) vs the
        explicit window-slicing python-loop oracle — exact, including the
        overlapping-stride geometry."""
        conv = ConvSpec(kernel=kernel, stride=stride, n_channels=16)
        frame = self._frame()
        w = jax.random.normal(jax.random.PRNGKey(1),
                              (16, kernel * kernel)) * 3.0
        got = ops.ip2_conv(frame, w, conv, interpret=True)
        gh, gw = conv.out_grid(32, 32)
        assert got.shape == (2, gh * gw, 16)
        w_q, _ = pwm_mod.quantize_weights(w, conv.quant)
        params = ops.kernel_params_from_spec(conv.patch_spec())
        want = ref.ip2_conv_ref(frame, w_q.T, jnp.zeros((16,)), conv, params)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)

    def test_conv_code_and_sign_epilogues(self):
        """The whole mode-selectable epilogue applies per window: fused
        int8 codes and the 1-bit sign wire both ride the conv path."""
        conv = ConvSpec(kernel=8, stride=8, n_channels=16)
        adc = adc_mod.ADCSpec(bits=8)
        frame = self._frame()
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 64)) * 3.0
        bias = jax.random.normal(jax.random.PRNGKey(2), (16,)) * 0.1
        codes = ops.ip2_conv(frame, w, conv, adc=adc, bias=bias, codes=True,
                             interpret=True)
        assert codes.dtype == jnp.int8
        w_q, _ = pwm_mod.quantize_weights(w, conv.quant)
        params = ops.kernel_params_from_spec(conv.patch_spec(), adc,
                                             codes=True)
        want = ref.ip2_conv_ref(frame, w_q.T, bias, conv, params)
        np.testing.assert_array_equal(np.asarray(codes), np.asarray(want))

        bits = ops.ip2_conv(frame, w, conv, readout="sign", interpret=True)
        assert bits.dtype == jnp.bool_
        params_s = ops.kernel_params_from_spec(conv.patch_spec(),
                                               readout="sign")
        want_s = ref.ip2_conv_ref(frame, w_q.T, jnp.zeros((16,)), conv,
                                  params_s)
        np.testing.assert_array_equal(np.asarray(bits),
                                      np.asarray(want_s.astype(bool)))


# ---------------------------------------------------------------------------
# event ledgers: what each mode spends
# ---------------------------------------------------------------------------

class TestModeEvents:
    def test_frontend_events_sign_swaps_conversion_channel(self):
        adc_ev = power_mod.frontend_frame_events(4096.0, 64, 32, 16.0, 16.0)
        sgn_ev = power_mod.frontend_frame_events(4096.0, 64, 32, 16.0, 16.0,
                                                 readout="sign")
        assert adc_ev.adc_conversions == 16 * 32
        assert adc_ev.sign_comparisons == 0.0
        assert sgn_ev.adc_conversions == 0.0
        assert sgn_ev.sign_comparisons == 16 * 32
        # everything that is not the conversion channel is identical: the
        # analog work (caps, PWM, CDS, dumps, DAC) does not care how the
        # result is read out
        for f in power_mod.EventCounts._fields:
            if f in ("adc_conversions", "sign_comparisons"):
                continue
            assert getattr(adc_ev, f) == getattr(sgn_ev, f), f
        with pytest.raises(ValueError, match="readout"):
            power_mod.frontend_frame_events(4096.0, 64, 32, 16.0, 16.0,
                                            readout="bogus")

    def test_conv_events_program_once_vs_reprogram(self):
        """The mode's defining cost asymmetry: a static kernel bank is
        programmed once at deploy (dac_reprograms = 0 per frame); cycling
        kernels through the bank reprograms C·K² DAC cells per frame —
        and the meter must see the difference."""
        kw = dict(n_pixels=1024.0, pixels_per_window=64, n_channels=16,
                  n_windows=49.0)
        once = power_mod.conv_frame_events(**kw)
        cyc = power_mod.conv_frame_events(reprogram=True, **kw)
        assert once.dac_reprograms == 0.0
        assert cyc.dac_reprograms == 16 * 64
        # overlap cost is explicit: every window charges its K² pixels
        assert once.cap_charges == 49 * 64 * 16
        assert once.pwm_pixel_frames == 49 * 64
        assert once.adc_conversions == 49 * 16
        m = power_mod.EnergyMeter()
        assert (m.power_mw(cyc, FRAME_HZ) > m.power_mw(once, FRAME_HZ))
        # sign readout composes with conv
        sgn = power_mod.conv_frame_events(readout="sign", **kw)
        assert sgn.adc_conversions == 0.0
        assert sgn.sign_comparisons == 49 * 16
        assert m.power_mw(sgn, FRAME_HZ) < m.power_mw(once, FRAME_HZ)

    def test_meter_prices_new_components(self):
        m = power_mod.EnergyMeter()
        ev = power_mod.EventCounts(sign_comparisons=1e6, dac_reprograms=100.0)
        rep = m.energy_j(ev, FRAME_HZ)
        assert rep["sign_comparators"] == pytest.approx(
            1e6 * m.k.e_sign_cmp_j)
        assert rep["weight_reprogram"] == pytest.approx(
            100.0 * m.k.e_dac_reprogram_j)
        # a comparator firing is orders of magnitude under an ADC ramp —
        # the whole point of the ADC-less tier
        assert m.k.e_sign_cmp_j < m.k.e_adc_j / 10.0

    def test_event_counts_arithmetic_covers_new_fields(self):
        a = power_mod.EventCounts(sign_comparisons=3.0, dac_reprograms=2.0)
        s = a.add(power_mod.EventCounts(sign_comparisons=1.0))
        assert s.sign_comparisons == 4.0 and s.dac_reprograms == 2.0
        assert a.scale(2.0).dac_reprograms == 4.0


# ---------------------------------------------------------------------------
# the sign wire through the frontend
# ---------------------------------------------------------------------------

class TestSignWireFrontend:
    def test_sign_wire_payload_and_ledger(self):
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        cf = apply_frontend(params, rgb, fcfg, mode="compact", wire="sign")
        assert cf.features.dtype == jnp.bool_
        # payload is 1 byte/bit in jax, but the WIRE is 1 bit: the affine
        # reconstructs ±v_mag + bias through the one dequant site
        deq = np.asarray(dequantize_features(cf))
        bias = np.asarray(params["bias"])
        lv = np.where(np.asarray(cf.features),
                      adc_mod.SIGN_V_MAG + bias[None, None, :],
                      -adc_mod.SIGN_V_MAG + bias[None, None, :])
        np.testing.assert_allclose(deq, lv, rtol=1e-6, atol=1e-7)
        # ledger: comparator firings, not ADC conversions
        ev = jax.tree.map(np.asarray, cf.events)
        k, m = fcfg.n_active, fcfg.patch.n_vectors
        np.testing.assert_array_equal(ev.sign_comparisons, k * m)
        np.testing.assert_array_equal(ev.adc_conversions, 0.0)

    def test_sign_kernel_adapter_matches_reference_path(self):
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        cf_ref = apply_frontend(params, rgb, fcfg, mode="compact",
                                wire="sign")
        fn = ops.ip2_sign_fn(fcfg.patch, interpret=True)
        cf_k = apply_frontend(params, rgb, fcfg, mode="compact",
                              wire="sign", project_fn=fn)
        np.testing.assert_array_equal(np.asarray(cf_ref.features),
                                      np.asarray(cf_k.features))
        np.testing.assert_array_equal(np.asarray(cf_ref.indices),
                                      np.asarray(cf_k.indices))

    def test_sign_wire_temporal_cache_discipline(self):
        fcfg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-5))
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        cache = init_feature_cache(fcfg, (2,), dtype=bool)
        for _ in range(3):
            cf, cache = apply_frontend(params, rgb, fcfg, mode="compact",
                                       wire="sign", cache=cache)
            assert cf.features.dtype == jnp.bool_
            assert cache.features.dtype == jnp.bool_
        # a code cache cannot serve the sign wire (and vice versa)
        with pytest.raises(ValueError, match="does not match wire"):
            apply_frontend(params, rgb, fcfg, mode="compact", wire="sign",
                           cache=init_feature_cache(fcfg, (2,)))
        with pytest.raises(ValueError, match="does not match wire"):
            apply_frontend(params, rgb, fcfg, mode="compact",
                           cache=init_feature_cache(fcfg, (2,), dtype=bool))

    def test_sign_wire_rejections(self):
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (1, 64, 64, 3))
        with pytest.raises(ValueError, match="requires analog=True"):
            apply_frontend(c.init_frontend_params(KEY, _fcfg(analog=False)),
                           rgb, _fcfg(analog=False), mode="compact",
                           wire="sign")
        sign_fn = ops.ip2_sign_fn(fcfg.patch, interpret=True)
        with pytest.raises(ValueError, match="sign"):
            apply_frontend(params, rgb, fcfg, mode="dense",
                           project_fn=sign_fn)
        with pytest.raises(ValueError, match="sign"):
            apply_frontend(params, rgb, fcfg, mode="compact", wire="codes",
                           project_fn=sign_fn)
        codes_fn = ops.ip2_codes_fn(fcfg.patch, fcfg.adc, interpret=True)
        with pytest.raises(ValueError, match="sign"):
            apply_frontend(params, rgb, fcfg, mode="compact", wire="sign",
                           project_fn=codes_fn)

    def test_sign_wire_embed_bypasses_w8a8(self):
        """quant_embed must not push the bool payload into the int8 w8a8
        kernel — the sign wire routes through the generic dequant, so
        quant_embed on/off is bitwise-identical on this wire."""
        fcfg = _fcfg()
        cfg = _vcfg(fcfg)
        cfg_q = dataclasses.replace(cfg, quant_embed=True)
        params = init_vit(KEY, cfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        lp, _ = vit_forward_compact(params, rgb, cfg, wire="sign")
        lq, _ = vit_forward_compact(params, rgb, cfg_q, wire="sign")
        np.testing.assert_array_equal(np.asarray(lp), np.asarray(lq))
        assert np.isfinite(np.asarray(lp)).all()


# ---------------------------------------------------------------------------
# per-slot sign degradation in the compact forward (the engine's knob)
# ---------------------------------------------------------------------------

class TestVitSignMode:
    def _setup(self):
        fcfg = _fcfg()
        cfg = _vcfg(fcfg)
        params = init_vit(KEY, cfg)
        rgb = jax.random.uniform(KEY, (3, 64, 64, 3))
        return cfg, params, rgb

    def test_all_false_mask_is_bitwise_noop(self):
        cfg, params, rgb = self._setup()
        base, aux_b = vit_forward_compact(params, rgb, cfg)
        off, aux_o = vit_forward_compact(
            params, rgb, cfg, sign_mode=jnp.zeros((3,), bool))
        np.testing.assert_array_equal(np.asarray(base), np.asarray(off))
        for e_b, e_o in zip(aux_b["events"], aux_o["events"]):
            np.testing.assert_array_equal(np.asarray(e_b), np.asarray(e_o))

    def test_per_row_degradation_and_ledger_swap(self):
        cfg, params, rgb = self._setup()
        sm = jnp.array([True, False, True])
        lm, aux = vit_forward_compact(params, rgb, cfg, sign_mode=sm)
        l_all, _ = vit_forward_compact(params, rgb, cfg,
                                       sign_mode=jnp.ones((3,), bool))
        l_off, _ = vit_forward_compact(params, rgb, cfg,
                                       sign_mode=jnp.zeros((3,), bool))
        # flagged rows equal the all-flagged batch, unflagged the clean one
        np.testing.assert_array_equal(np.asarray(lm[0]), np.asarray(l_all[0]))
        np.testing.assert_array_equal(np.asarray(lm[2]), np.asarray(l_all[2]))
        np.testing.assert_array_equal(np.asarray(lm[1]), np.asarray(l_off[1]))
        assert np.abs(np.asarray(lm[0]) - np.asarray(l_off[0])).max() > 0
        ev = jax.tree.map(np.asarray, aux["events"])
        m = cfg.frontend.patch.n_vectors
        k = cfg.frontend.n_active
        np.testing.assert_array_equal(ev.adc_conversions, [0.0, k * m, 0.0])
        np.testing.assert_array_equal(ev.sign_comparisons, [k * m, 0.0, k * m])

    def test_sign_mode_needs_code_wire(self):
        cfg, params, rgb = self._setup()
        with pytest.raises(ValueError, match="code wire"):
            vit_forward_compact(params, rgb, cfg, wire="float",
                                sign_mode=jnp.ones((3,), bool))


# ---------------------------------------------------------------------------
# governor: the ADC-less tier below the k ladder
# ---------------------------------------------------------------------------

def make_gov_cfg():
    fcfg = FrontendConfig(
        image_h=64, image_w=64, aa_cutoff=None,
        patch=PatchSpec(patch_h=8, patch_w=8, n_vectors=64),
        active_fraction=0.25,
        temporal=TemporalSpec(delta_threshold=1e-4),
    )
    return ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)


GCFG = make_gov_cfg()
GPARAMS = init_vit(KEY, GCFG)
GFRAMES = np.asarray(jax.random.uniform(jax.random.PRNGKey(1),
                                        (24, 64, 64, 3)))
GK = GCFG.frontend.n_active


def _floor_mw(spec: GovernorSpec) -> float:
    """The finest-k-tier floor allocation the sign tier undercuts."""
    meter = power_mod.EnergyMeter()
    slot_mw = 1e3 * meter.slot_recompute_power_w(64, 64, FRAME_HZ)
    k_min = spec.tier_tokens(GK)[-1]
    fixed = gov_mod.fixed_power_mw(
        meter, 64.0 * 64.0, 64, 64,
        jnp.asarray([k_min], jnp.float32), FRAME_HZ)
    return float(fixed[0]) + spec.floor * slot_mw


class TestGovernorSignTier:
    def test_spec_and_helpers(self):
        spec = GovernorSpec(budget_mw=1.0)
        assert spec.sign_tier is False
        t = jnp.array([0, 3, 4, 9])
        assert not np.asarray(gov_mod.tier_is_sign(spec, t)).any()
        s2 = GovernorSpec(budget_mw=1.0, sign_tier=True)
        np.testing.assert_array_equal(
            np.asarray(gov_mod.tier_is_sign(s2, t)),
            [False, False, True, True])
        # tier_k_eff clamps: the sign tier keeps the finest tier's tokens
        toks = s2.tier_tokens(GK)
        np.testing.assert_array_equal(
            np.asarray(gov_mod.tier_k_eff(s2, t, GK)),
            [toks[0], toks[3], toks[3], toks[3]])

    def test_engine_degrades_into_sign_tier_and_recovers(self):
        spec0 = GovernorSpec(budget_mw=1.0, sign_tier=True)
        budget = 0.8 * _floor_mw(spec0)
        gov = GovernorSpec(budget_mw=budget, sign_tier=True)
        eng = SaccadeEngine(GCFG, GPARAMS, capacity=1, temporal=True,
                            frame_hz=FRAME_HZ, governor=gov)
        eng.admit("a")
        for t in range(12):
            logits = eng.step({"a": GFRAMES[t % len(GFRAMES)]})["a"]
            assert np.isfinite(logits).all()
        assert eng.sign_readout("a")
        assert int(eng.state.controls.tier[0]) == len(gov.k_tiers)
        assert eng.k_tier("a") == gov.tier_tokens(GK)[-1]
        # the ledger switched channels: comparators fire, the ADC is off
        ev = eng.events("a", "last")
        assert ev.adc_conversions == 0.0
        assert ev.sign_comparisons > 0.0
        # serving now costs less than even the finest k tier's floor —
        # the whole reason the tier exists
        assert eng.power_mw("a") < _floor_mw(gov)
        assert int(eng.state.frame_age[0]) == 12      # degraded, not stalled

        # budget relief: the slot climbs back out of the sign tier (with
        # hysteresis, one tier per frame) and the ADC comes back
        eng.set_budget_mw(100.0)
        for t in range(12):
            eng.step({"a": GFRAMES[t % len(GFRAMES)]})
        assert not eng.sign_readout("a")
        assert eng.events("a", "last").adc_conversions > 0.0
        assert eng.k_tier("a") == GK
        assert eng.n_traces == 1                      # zero recompiles

    def test_sign_tier_flag_is_noop_under_slack_budget(self):
        """Enabling sign_tier changes NOTHING while the budget is slack:
        bitwise-identical logits and state vs the plain governed engine."""
        a = SaccadeEngine(GCFG, GPARAMS, capacity=1, temporal=True,
                          frame_hz=FRAME_HZ,
                          governor=GovernorSpec(budget_mw=100.0))
        b = SaccadeEngine(GCFG, GPARAMS, capacity=1, temporal=True,
                          frame_hz=FRAME_HZ,
                          governor=GovernorSpec(budget_mw=100.0,
                                                sign_tier=True))
        a.admit("s"); b.admit("s")
        for t in range(6):
            la = a.step({"s": GFRAMES[t]})["s"]
            lb = b.step({"s": GFRAMES[t]})["s"]
            np.testing.assert_array_equal(la, lb)
        np.testing.assert_array_equal(
            np.asarray(a.state.cache.features),
            np.asarray(b.state.cache.features))
        assert not b.sign_readout("s")

    def test_sign_readout_accessor_requires_governor(self):
        eng = SaccadeEngine(GCFG, GPARAMS, capacity=1, temporal=True)
        eng.admit("a")
        with pytest.raises(RuntimeError, match="governor"):
            eng.sign_readout("a")
