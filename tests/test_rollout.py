"""Device-resident saccade rollouts + async dispatch (DESIGN.md §15).

The tentpole contract this file pins: ``step_rollout(T)`` — one
``lax.scan`` dispatch over T ticks — is BITWISE identical to T
sequential ``step()`` calls, logits AND the full carried StreamState
(indices / EMA / temporal cache / backend cache / meters / governor
controls), in EVERY engine mode. Plus: one trace per distinct T (reused
Ts hit the jit cache), the governed slack-budget no-op survives the
scan, async handles are lazy and idempotent, and a stateful fuzz
(hypothesis-driven when installed, deterministic battery always) holds
the parity under random T, churn between rollouts, partial-fed tick
masks, and frame-rate skew — against both the per-tick ``step()``
oracle and dedicated per-stream batch-1 loops.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.frontend import FrontendConfig
from repro.core.projection import PatchSpec
from repro.core.temporal import TemporalSpec
from repro.models.vit import ViTConfig, init_vit
from repro.serve.engine import RolloutHandle, SaccadeEngine, StepHandle
from repro.serve.fleet import SaccadeFleet
from repro.serve.governor import GovernorSpec
from repro.serve.serve_step import make_bootstrap_indices, make_saccade_step

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

KEY = jax.random.PRNGKey(0)


def _cfg(temporal=False):
    kw = dict(temporal=TemporalSpec(delta_threshold=1e-4)) if temporal else {}
    fcfg = FrontendConfig(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
        active_fraction=0.25, **kw,
    )
    return ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2,
                     d_ff=64)


CFG = _cfg()
CFG_T = _cfg(temporal=True)
PARAMS = init_vit(KEY, CFG)
PARAMS_T = init_vit(KEY, CFG_T)
# moving-scene frames so the temporal gate / governor actually have work
FRAMES = np.asarray(
    jax.random.uniform(jax.random.PRNGKey(1), (16, 64, 64, 3)))

# Engine modes the acceptance pins parity over. The governed budgets are
# deliberately tight so the in-scan control law MOVES during the rollout
# (parity would hold for any budget; a slack one wouldn't exercise it).
MODES = {
    "plain": (CFG, PARAMS, {}),
    "temporal": (CFG_T, PARAMS_T, dict(temporal=True)),
    "backend_delta": (CFG, PARAMS, dict(backend_delta=True)),
    "temporal_governed": (
        CFG_T, PARAMS_T,
        dict(temporal=True, governor=GovernorSpec(budget_mw=0.05))),
    "sign_tier_governed": (
        CFG_T, PARAMS_T,
        dict(temporal=True,
             governor=GovernorSpec(budget_mw=0.02, sign_tier=True))),
    "temporal_backend_governed": (
        CFG_T, PARAMS_T,
        dict(temporal=True, backend_delta=True,
             governor=GovernorSpec(budget_mw=0.05, backend_eps=1e-3))),
}

# a T=5 schedule with partial-fed ticks and frame-rate skew: "a" is fed
# every tick, "b" every other tick, "c" once, tick 3 feeds nobody
SCHED = [
    {"a": FRAMES[0], "b": FRAMES[1]},
    {"a": FRAMES[2]},
    {"a": FRAMES[3], "b": FRAMES[4], "c": FRAMES[5]},
    {},
    {"a": FRAMES[6], "b": FRAMES[7]},
]


def assert_states_bitwise(a: SaccadeEngine, b: SaccadeEngine, msg=""):
    la, lb = jax.tree.leaves(a.state), jax.tree.leaves(b.state)
    assert len(la) == len(lb)
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y),
            err_msg=f"{msg} state leaf {i} diverged")


def assert_rollout_matches_sequential(eng_seq, eng_roll, sched, msg=""):
    """The core acceptance check: run ``sched`` per-tick on one engine
    and as ONE rollout on the other; logits per tick and the final
    state must be bitwise equal."""
    seq = [eng_seq.step(fr) for fr in sched]
    roll = eng_roll.step_rollout(sched)
    assert len(roll) == len(seq)
    for t, (want, got) in enumerate(zip(seq, roll)):
        assert set(want) == set(got), f"{msg} tick {t}: fed cover differs"
        for sid in want:
            np.testing.assert_array_equal(
                want[sid], got[sid],
                err_msg=f"{msg} tick {t} stream {sid}: logits diverged")
    assert_states_bitwise(eng_seq, eng_roll, msg=msg)


class TestBitwiseParity:
    """step_rollout(T) == T x step(), bitwise, in every engine mode."""

    @pytest.mark.parametrize("mode", sorted(MODES))
    def test_rollout_matches_sequential(self, mode):
        cfg, params, kw = MODES[mode]
        eng_seq = SaccadeEngine(cfg, params, capacity=4, **kw)
        eng_roll = SaccadeEngine(cfg, params, capacity=4, **kw)
        for e in (eng_seq, eng_roll):
            for sid in ("a", "b", "c"):
                e.admit(sid)
        assert_rollout_matches_sequential(eng_seq, eng_roll, SCHED, mode)
        # and AGAIN on warm state — the carry (caches, meters, governor
        # knobs) round-trips through the scan, not just the first frames
        sched2 = [{"a": FRAMES[8], "c": FRAMES[9]}, {"b": FRAMES[10]},
                  {"a": FRAMES[11], "b": FRAMES[12], "c": FRAMES[13]}]
        assert_rollout_matches_sequential(eng_seq, eng_roll, sched2,
                                          mode + " (warm)")

    def test_governed_knobs_actually_moved(self):
        """Guard the guard: the tight-budget configs must drive at least
        one slot off the no-op tier during the rollout, otherwise the
        governed parity cases never exercised the in-scan control law."""
        cfg, params, kw = MODES["sign_tier_governed"]
        eng = SaccadeEngine(cfg, params, capacity=2, **kw)
        eng.admit("a")
        eng.step_rollout([{"a": FRAMES[t]} for t in range(8)])
        assert eng.k_tier("a") < cfg.frontend.n_active

    def test_slack_budget_rollout_is_bitwise_noop(self):
        """DESIGN.md §15 acceptance: with a slack budget the GOVERNED
        rollout is bitwise the UNGOVERNED temporal rollout — the in-scan
        control law holds every knob at its no-op value, tick after
        tick, inside the scan exactly as across single steps."""
        plain = SaccadeEngine(CFG_T, PARAMS_T, capacity=2, temporal=True)
        gvd = SaccadeEngine(CFG_T, PARAMS_T, capacity=2, temporal=True,
                            governor=GovernorSpec(budget_mw=100.0))
        plain.admit("a"); gvd.admit("a")
        sched = [{"a": FRAMES[0 if t != 3 else 5]} for t in range(6)]
        out_p = plain.step_rollout(sched)
        out_g = gvd.step_rollout(sched)
        for t in range(len(sched)):
            np.testing.assert_array_equal(out_p[t]["a"], out_g[t]["a"])
        np.testing.assert_array_equal(
            np.asarray(plain.state.cache.features),
            np.asarray(gvd.state.cache.features))
        np.testing.assert_array_equal(
            np.asarray(plain.state.indices), np.asarray(gvd.state.indices))
        k = CFG_T.frontend.n_active
        assert gvd.recompute_cap("a") == k and gvd.k_tier("a") == k


class TestTraceDiscipline:
    def test_one_trace_per_distinct_T_and_reuse(self):
        eng = SaccadeEngine(CFG, PARAMS, capacity=2)
        eng.admit("a")
        mk = lambda T: [{"a": FRAMES[t % len(FRAMES)]} for t in range(T)]
        assert eng.n_rollout_traces == 0
        eng.step_rollout(mk(3))
        assert eng.n_rollout_traces == 1
        eng.step_rollout(mk(3))                  # reused T: cache hit
        assert eng.n_rollout_traces == 1
        eng.step_rollout(mk(5))                  # new T: one more trace
        assert eng.n_rollout_traces == 2
        eng.step_rollout(mk(3)); eng.step_rollout(mk(5))
        assert eng.n_rollout_traces == 2
        # churn between rollouts must not retrace either path
        eng.admit("b"); eng.evict("a")
        eng.step_rollout([{"b": FRAMES[0]}, {"b": FRAMES[1]}, {}])
        assert eng.n_rollout_traces == 2
        # and the single-step path keeps ITS one-compile contract
        eng.step({"b": FRAMES[2]})
        eng.step({"b": FRAMES[3]})
        assert eng.n_traces == 1


class TestAsyncHandles:
    def test_step_handle_is_lazy_and_idempotent(self):
        eng = SaccadeEngine(CFG, PARAMS, capacity=2)
        eng.admit("a"); eng.admit("b")
        h = eng.step({"a": FRAMES[0]}, block=False)
        assert isinstance(h, StepHandle)
        out = h.result()
        assert set(out) == {"a"}                 # fed streams only
        assert h.result() is out                 # cached, device ref dropped
        # empty tick: still a handle, empty result
        h0 = eng.step({}, block=False)
        assert h0.result() == {}

    def test_rollout_handle_one_fetch_many_ticks(self):
        eng = SaccadeEngine(CFG, PARAMS, capacity=2)
        eng.admit("a"); eng.admit("b")
        h = eng.step_rollout(
            [{"a": FRAMES[0]}, {}, {"a": FRAMES[1], "b": FRAMES[2]}],
            block=False)
        assert isinstance(h, RolloutHandle)
        out = h.result()
        assert [set(d) for d in out] == [{"a"}, set(), {"a", "b"}]
        assert h.result() is out
        assert eng.step_rollout([]) == []        # zero-length: no dispatch

    def test_dispatch_overlaps_across_engines(self):
        """The async contract the fleet layer relies on: a second
        engine's step can be DISPATCHED before the first engine's result
        is fetched, and both handles then resolve correctly."""
        e1 = SaccadeEngine(CFG, PARAMS, capacity=1)
        e2 = SaccadeEngine(CFG, PARAMS, capacity=1)
        e1.admit("x"); e2.admit("y")
        h1 = e1.step({"x": FRAMES[0]}, block=False)
        h2 = e2.step({"y": FRAMES[0]}, block=False)
        o1, o2 = h1.result(), h2.result()
        # identical params+frame => identical logits, whichever engine
        np.testing.assert_array_equal(o1["x"], o2["y"])

    def test_rollout_unknown_stream_raises_with_tick(self):
        eng = SaccadeEngine(CFG, PARAMS, capacity=1)
        eng.admit("a")
        with pytest.raises(ValueError, match="tick 1.*unknown"):
            eng.step_rollout([{"a": FRAMES[0]}, {"zzz": FRAMES[1]}])


class TestFleetRollout:
    def test_fleet_rollout_matches_fleet_steps(self):
        f_seq = SaccadeFleet(CFG, PARAMS, n_hosts=2, capacity=2)
        f_roll = SaccadeFleet(CFG, PARAMS, n_hosts=2, capacity=2)
        for f in (f_seq, f_roll):
            for sid in ("a", "b", "c"):
                f.submit(sid)
            f.drain()
        sched = [{"a": FRAMES[0], "c": FRAMES[1]}, {"b": FRAMES[2]},
                 {"a": FRAMES[3], "b": FRAMES[4], "c": FRAMES[5]}]
        seq = [f_seq.step(fr) for fr in sched]
        roll = f_roll.step_rollout(sched)
        for t in range(len(sched)):
            assert set(seq[t]) == set(roll[t])
            for sid in seq[t]:
                np.testing.assert_array_equal(seq[t][sid], roll[t][sid])

    def test_fleet_async_dispatch_before_fetch(self):
        """fleet.step must dispatch every fed host before fetching any:
        instrument the engines' step to record dispatch order vs the
        handles' fetch order."""
        fleet = SaccadeFleet(CFG, PARAMS, n_hosts=2, capacity=1)
        fleet.submit("a"); fleet.submit("b")
        fleet.drain()
        events = []

        class TracedHandle:
            def __init__(self, handle, h):
                self._handle, self._h = handle, h

            def result(self):
                events.append(("fetch", self._h))
                return self._handle.result()

        for h_i, eng in enumerate(fleet.engines):
            inner = eng.step

            def spy(frames, block=True, _h=h_i, _inner=inner):
                events.append(("dispatch", _h))
                assert block is False, "fleet must dispatch non-blocking"
                return TracedHandle(_inner(frames, block=False), _h)

            eng.step = spy
        out = fleet.step({"a": FRAMES[0], "b": FRAMES[1]})
        assert set(out) == {"a", "b"}
        kinds = [k for k, _ in events]
        assert kinds == ["dispatch", "dispatch", "fetch", "fetch"]
        # non-blocking fleet handle: no fetch until result()
        events.clear()
        h = fleet.step({"a": FRAMES[2], "b": FRAMES[3]}, block=False)
        assert [k for k, _ in events] == ["dispatch", "dispatch"]
        h.result()
        assert [k for k, _ in events] == ["dispatch", "dispatch",
                                          "fetch", "fetch"]


# ---------------------------------------------------------------------------
# stateful fuzz: rollouts vs the per-tick oracle under churn + skew
# ---------------------------------------------------------------------------

def run_rollout_fuzz(seed: int, n_rounds: int = 5, temporal: bool = False):
    """One fuzz episode: random admit/evict churn BETWEEN rollouts,
    rollouts of random T with partial-fed tick masks and frame-rate
    skew. Engine A replays every tick through ``step()`` (the oracle),
    engine B serves whole rollouts; parity must be bitwise after every
    round. Fed streams are additionally checked against their own
    dedicated batch-1 single-stream loop (the dense per-stream oracle
    from the engine fuzz), and the trace ledger must show exactly one
    rollout trace per distinct T.
    """
    cfg, params = (CFG_T, PARAMS_T) if temporal else (CFG, PARAMS)
    kw = dict(temporal=True) if temporal else {}
    capacity = 3
    eng_o = SaccadeEngine(cfg, params, capacity=capacity, **kw)
    eng_r = SaccadeEngine(cfg, params, capacity=capacity, **kw)
    boot = jax.jit(make_bootstrap_indices(cfg))
    step1 = jax.jit(make_saccade_step(cfg, temporal=temporal))

    rng = np.random.default_rng(7000 + seed)
    live: list = []
    refs: dict = {}                      # sid -> [indices, cache, n_fed]
    next_id = 0
    ts_seen: set[int] = set()

    for _ in range(n_rounds):
        # churn at the rollout boundary only (admit/evict are host ops)
        for _ in range(int(rng.integers(0, 3))):
            if live and rng.random() < 0.4:
                sid = live.pop(int(rng.integers(len(live))))
                eng_o.evict(sid); eng_r.evict(sid)
                del refs[sid]
            elif len(live) < capacity:
                sid = f"s{next_id}"; next_id += 1
                eng_o.admit(sid); eng_r.admit(sid)
                live.append(sid)
                refs[sid] = [None, None, 0]
        if not live:
            continue
        T = int(rng.integers(1, 5))
        ts_seen.add(T)
        sched = []
        for _t in range(T):
            # frame-rate skew: feed each live stream with p=0.6
            fed = [sid for sid in live if rng.random() < 0.6]
            sched.append({
                sid: FRAMES[(refs[sid][2] + int(sid[1:])) % len(FRAMES)]
                for sid in fed})
            for sid in fed:
                refs[sid][2] += 1
        seq = [eng_o.step(fr) for fr in sched]
        roll = eng_r.step_rollout(sched)
        for t in range(T):
            assert set(seq[t]) == set(roll[t])
            for sid in seq[t]:
                np.testing.assert_array_equal(
                    seq[t][sid], roll[t][sid],
                    err_msg=f"seed {seed} tick {t} stream {sid}")
        assert_states_bitwise(eng_o, eng_r, msg=f"seed {seed}")
        # per-stream dense oracle: each fed stream tracks its own
        # batch-1 loop over exactly the frames it saw
        for t, fr in enumerate(sched):
            for sid, frame in fr.items():
                r = jnp.asarray(frame)[None]
                if refs[sid][0] is None:
                    refs[sid][0] = boot(params, r)
                    if temporal:
                        from repro.core.temporal import init_feature_cache
                        refs[sid][1] = init_feature_cache(cfg.frontend, (1,))
                if temporal:
                    logits, refs[sid][0], _, refs[sid][1] = step1(
                        params, r, refs[sid][0], refs[sid][1])
                else:
                    logits, refs[sid][0], _ = step1(params, r, refs[sid][0])
                np.testing.assert_allclose(
                    roll[t][sid], np.asarray(logits[0]), atol=1e-5,
                    err_msg=f"seed {seed}: {sid} diverged from its "
                            f"dedicated loop at tick {t}")
    assert eng_r.n_rollout_traces == len(ts_seen)
    assert eng_o.n_traces <= 1 and eng_r.n_traces == 0


class TestStatefulFuzzRollout:
    @pytest.mark.parametrize("seed", [0, 1])
    def test_deterministic_battery(self, seed):
        run_rollout_fuzz(seed)

    def test_deterministic_battery_temporal(self):
        run_rollout_fuzz(2, n_rounds=4, temporal=True)

    if HAVE_HYPOTHESIS:

        @settings(max_examples=5, deadline=None)
        @given(seed=st.integers(min_value=10, max_value=10_000))
        def test_hypothesis_random_episodes(self, seed):
            run_rollout_fuzz(seed, n_rounds=3)
