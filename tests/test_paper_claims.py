"""Golden-value regression tests pinning the paper-facing derived numbers
that `benchmarks/run.py` otherwise only prints into BENCH_throughput.json:
Table 1 area/pitch, the <60 mW @ 2 Mpix/30 Hz and <30 mW/Mpix power
claims, the 10 µs droop datum (0.5 V -> 0.45 V passive), the Fig. 3
operating points, and the 10x/30x data-reduction factors.

A core/power-model change that silently breaks a paper claim must fail
tier-1, not just the bench job."""

import math

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.power import (
    AreaBudget, EnergyConstants, SensorConfig, data_reduction, power_report,
)
from repro.core.switched_cap import (
    SummerSpec, TAU_LEAK_22NM_FDX_S, TAU_LEAK_65NM_S,
    charge_share_sum, passive_droop_trace,
)
from repro.core.throughput import frame_rate, rate_point


class TestTable1Area:
    def test_total_and_pitch(self):
        """Table 1: 485 µm² in-pixel circuit -> 22.0 µm pixel pitch."""
        totals = AreaBudget().totals()
        assert totals["Total"]["total_um2"] == 485.0
        assert totals["Total"]["pitch_um"] == pytest.approx(22.0, abs=0.05)

    def test_row_inventory(self):
        """The budget is the paper's: photodiode + 3 caps + 41 transistors
        + wiring + margin (a dropped row would silently shrink the pitch)."""
        totals = AreaBudget().totals()
        assert totals["Cap 30 fF"]["count"] == 3
        assert totals["Transistors"]["count"] == 41
        assert totals["Photo Sensor"]["total_um2"] == 64.0
        # occupancies sum to 1 over the physical rows
        occ = sum(v["occupancy"] for k, v in totals.items() if k != "Total")
        assert occ == pytest.approx(1.0)


class TestPowerClaims:
    def test_2mpix_30hz_under_60mw(self):
        rep = power_report(SensorConfig())
        assert rep.total_w * 1e3 < 60.0
        # and not vacuously small — the model is calibrated, not zeroed
        assert rep.total_w * 1e3 > 20.0

    def test_under_30mw_per_mpix(self):
        rep = power_report(SensorConfig())
        assert 10.0 < rep.mw_per_mpix < 30.0

    def test_adc_is_majority_consumer(self):
        """Paper: 'the majority of the power is for the ADC conversion'."""
        rep = power_report(SensorConfig())
        assert rep.adc_dominated and rep.dominant == "adc"
        others = {k: v for k, v in rep.components.items() if k != "adc"}
        assert rep.components["adc"] > max(others.values())

    def test_active_fraction_gates_conversion_power(self):
        """The <30 mW/Mpix figure assumes 25 % active patches; converting
        every patch must blow through it (the claim depends on gating)."""
        full = power_report(SensorConfig(active_fraction=1.0))
        assert full.mw_per_mpix > 30.0


class TestDroopClaims:
    def test_10us_passive_droop_datum(self):
        """§2.1.2: 768 caps @1V + 768 @0V -> expected 0.5 V; the passive
        65 nm summer reads 0.45 V after the 10 µs hold (10 % droop)."""
        v = jnp.concatenate([jnp.ones(768), jnp.zeros(768)])
        out = float(charge_share_sum(v, SummerSpec(mode="passive")))
        assert out == pytest.approx(0.45, abs=1e-3)

    def test_tau_calibration(self):
        """tau is calibrated so exp(-10us/tau) == 0.9 exactly."""
        assert math.exp(-10e-6 / TAU_LEAK_65NM_S) == pytest.approx(0.9, rel=1e-9)
        trace = passive_droop_trace(jnp.float32(0.5), jnp.asarray([10e-6]))
        assert float(trace[0]) == pytest.approx(0.45, rel=1e-5)

    def test_opamp_holds_the_half_volt(self):
        v = jnp.concatenate([jnp.ones(768), jnp.zeros(768)])
        out = float(charge_share_sum(v, SummerSpec(mode="opamp")))
        assert out == pytest.approx(0.5, abs=1e-3)

    def test_22nm_fdx_barely_leaks(self):
        v = jnp.concatenate([jnp.ones(768), jnp.zeros(768)])
        out = float(charge_share_sum(
            v, SummerSpec(mode="passive", tau_leak_s=TAU_LEAK_22NM_FDX_S)))
        assert out > 0.499


class TestThroughputClaims:
    def test_1080p_c2_400vec_is_90hz(self):
        """Fig. 3 operating point: 1080p, C=2 weight lines, 400 vectors per
        32x32 patch -> ~90 Hz."""
        op = rate_point("1080p", 2, 32, 400)
        assert 85.0 <= op.frame_hz <= 95.0

    def test_8x8_192vec_exceeds_30hz(self):
        assert frame_rate(8, 192, 2) > 30.0

    def test_more_weight_lines_is_faster(self):
        rates = [frame_rate(32, 400, c) for c in (1, 2, 4, 8)]
        assert rates == sorted(rates) and rates[-1] > rates[0]


class TestDataReductionClaims:
    def test_10x_vs_bayer_raw(self):
        assert 10.0 <= data_reduction(SensorConfig()) < 12.0

    def test_30x_vs_interpolated_rgb(self):
        assert 30.0 <= data_reduction(SensorConfig(), vs_rgb=True) < 36.0

    def test_reduction_scales_with_gating(self):
        """Halving the active fraction doubles the reduction — the claim
        is a linear function of the saccade gate."""
        base = data_reduction(SensorConfig())
        half = data_reduction(SensorConfig(active_fraction=0.125))
        assert half == pytest.approx(2.0 * base, rel=1e-6)
