"""End-to-end behaviour tests for the IP2 system (paper-level claims wired
through the full stack: frontend physics -> kernels -> backend -> training
-> serving)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as c
import repro.optim as O
from repro.core.frontend import FrontendConfig
from repro.core.projection import PatchSpec
from repro.data.pipeline import SceneStream
from repro.kernels import ops
from repro.models.vit import (
    ViTConfig, init_vit, vit_forward, vit_forward_compact, vit_loss,
)

KEY = jax.random.PRNGKey(0)


def _fcfg(**kw):
    base = dict(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
        active_fraction=0.25,
    )
    base.update(kw)
    return FrontendConfig(**base)


class TestFrontendPipeline:
    def test_end_to_end_shapes_and_reduction(self):
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (3, 64, 64, 3))
        feats, mask = c.apply_frontend(params, rgb, fcfg)
        assert feats.shape == (3, 16, 32) and mask.shape == (3, 16)
        compact, idx = c.compact_features(feats, mask, fcfg)
        assert compact.shape == (3, 4, 32)
        # bandwidth: 4 patches x 32 vec = 128 features vs 64*64 Bayer px
        assert (64 * 64) / compact.shape[1] / compact.shape[2] >= 10.0
        assert not bool(jnp.isnan(feats).any())

    def test_masked_patches_contribute_nothing(self):
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (1, 64, 64, 3))
        mask = jnp.zeros((1, 16), bool).at[0, 3].set(True)
        feats, _ = c.apply_frontend(params, rgb, fcfg, mask=mask)
        assert float(jnp.abs(feats[0, 0]).max()) == 0.0   # deselected -> no ADC
        assert float(jnp.abs(feats[0, 3]).max()) > 0.0

    def test_kernel_path_equals_reference_path_in_frontend(self):
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        mask = jnp.ones((2, 16), bool)
        f_ref, _ = c.apply_frontend(params, rgb, fcfg, mask=mask)
        f_k, _ = c.apply_frontend(
            params, rgb, fcfg, mask=mask,
            project_fn=ops.ip2_project_fn(fcfg.patch, interpret=True),
        )
        np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_ref), atol=1e-5)

    def test_halfres_bayer_pipeline(self):
        """§2.1.5: the AA'd half-resolution Bayer sensor still produces
        well-scaled features (the accuracy claim is in bench_accuracy)."""
        fcfg = _fcfg(image_h=32, image_w=32,
                     patch=PatchSpec(patch_h=8, patch_w=8, n_vectors=16),
                     aa_cutoff=0.25)
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        half = rgb[:, ::2, ::2, :]            # ½-res sensor (960x540 analogue)
        feats, _ = c.apply_frontend(params, half, fcfg)
        assert feats.shape == (2, 16, 16)
        assert 0.01 < float(jnp.std(feats)) < 1.0   # ADC range used, not clipped


class TestCompactDataflow:
    """select -> gather -> project: the compact path must be bit-identical
    (up to dtype/order-of-summation) to the dense-then-mask path."""

    def test_compact_features_equal_dense_gather_same_mask(self):
        """The compact payload is int8 ADC codes (the wire format, §9);
        dequantized at the one permitted site they equal the dense float
        path bit for bit (no requant anywhere on the seam)."""
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (3, 64, 64, 3))
        dense, mask = c.apply_frontend(params, rgb, fcfg)
        cf = c.apply_frontend(params, rgb, fcfg, mask=mask, mode="compact")
        gathered = jnp.take_along_axis(dense, cf.indices[..., None], axis=-2)
        assert cf.features.shape == (3, 4, 32)
        assert cf.features.dtype == jnp.int8          # code-width wire
        assert bool(cf.valid.all())
        np.testing.assert_array_equal(
            np.asarray(c.dequantize_features(cf)), np.asarray(gathered)
        )

    def test_compact_with_kernel_project_fn(self):
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        cf_ref = c.apply_frontend(params, rgb, fcfg, mode="compact")
        cf_k = c.apply_frontend(
            params, rgb, fcfg, mode="compact", indices=cf_ref.indices,
            project_fn=ops.ip2_project_fn(fcfg.patch, interpret=True),
        )
        np.testing.assert_allclose(
            np.asarray(cf_k.features), np.asarray(cf_ref.features), atol=1e-5
        )

    def test_sparse_kernel_matches_compact_frontend(self):
        """The fused scalar-prefetch kernel (gather inside the kernel)
        computes the same features as gather-then-project."""
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        patches, weights = c.sensor_patches(params, rgb, fcfg)
        idx = c.topk_patch_indices(c.patch_energy(patches), fcfg.n_active)
        feats_k = ops.ip2_project_sparse(
            patches, weights, idx, fcfg.patch,
            adc=fcfg.adc, bias=params["bias"], interpret=True,
        )
        cf = c.apply_frontend(params, rgb, fcfg, mode="compact", indices=idx)
        np.testing.assert_allclose(
            np.asarray(feats_k), np.asarray(c.dequantize_features(cf)), atol=1e-5
        )
        # and in wire format: the kernel's fused epilogue emits the same
        # int8 codes the frontend streams (code grid == code grid)
        codes_k = ops.ip2_project_sparse(
            patches, weights, idx, fcfg.patch,
            adc=fcfg.adc, bias=params["bias"], codes=True, interpret=True,
        )
        assert codes_k.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(cf.features))

    @pytest.mark.parametrize("qth", [False, True])
    def test_vit_dense_vs_compact_equivalence(self, qth):
        """Same selection => identical logits from the (..., P) zero-masked
        grid and the (..., k) compact token layout."""
        fcfg = _fcfg()
        cfg = ViTConfig(frontend=fcfg, n_layers=2, d_model=64, n_heads=4,
                        d_ff=128, qth=qth)
        params = init_vit(KEY, cfg)
        rgb = jax.random.uniform(jax.random.PRNGKey(5), (3, 64, 64, 3))
        patches = c.extract_patches(c.mosaic(rgb), 16, 16)
        mask = c.topk_patch_mask(c.patch_energy(patches), 0.25)
        logits_dense = vit_forward(params, rgb, cfg, mask=mask)
        logits_compact, aux = vit_forward_compact(params, rgb, cfg, mask=mask)
        np.testing.assert_allclose(
            np.asarray(logits_dense), np.asarray(logits_compact), atol=2e-5
        )
        # backend saliency lives only on observed patches
        sal = np.asarray(aux["saliency"])
        m = np.asarray(mask)
        assert (sal[~m] == 0.0).all() and (sal[m] > 0.0).all()

    def test_vit_dense_vs_compact_fewer_than_k_active(self):
        fcfg = _fcfg()
        cfg = ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)
        params = init_vit(KEY, cfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        mask = jnp.zeros((2, 16), bool).at[:, 3].set(True).at[:, 11].set(True)
        logits_dense = vit_forward(params, rgb, cfg, mask=mask)
        logits_compact, _ = vit_forward_compact(params, rgb, cfg, mask=mask)
        np.testing.assert_allclose(
            np.asarray(logits_dense), np.asarray(logits_compact), atol=2e-5
        )

    def test_compact_path_ste_gradients_reach_frontend(self):
        """The co-design gradients flow through gather + STE quantizers on
        the compact path (not just the dense one) — via the float wire,
        whose values are bit-identical to dequantized codes (integer codes
        themselves carry no gradients; DESIGN.md §9)."""
        fcfg = _fcfg()
        cfg = ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)
        params = init_vit(KEY, cfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))

        def loss(p):
            logits, _ = vit_forward_compact(p, rgb, cfg, wire="float")
            return jnp.sum(logits ** 2)

        g = jax.grad(loss)(params)
        assert float(jnp.abs(g["ip2"]["a_rgb"]).max()) > 0.0
        assert float(jnp.abs(g["ip2"]["bias"]).max()) > 0.0


class TestCoDesignTraining:
    def test_ip2_vit_learns(self):
        """The analog frontend is trainable end-to-end (STE through PWM/DAC/
        ADC): accuracy on the shape task must beat chance by a wide margin
        within a small step budget."""
        cfg = ViTConfig(frontend=_fcfg(), n_classes=4, n_layers=2,
                        d_model=64, n_heads=4, d_ff=128)
        params = init_vit(KEY, cfg)
        opt = O.AdamWConfig(lr=2e-3, weight_decay=0.01)
        opt_state = O.init_opt_state(params, opt)
        stream = SceneStream(image=64)

        @jax.jit
        def step(params, opt_state, rgb, labels):
            (loss, acc), g = jax.value_and_grad(vit_loss, has_aux=True)(
                params, rgb, labels, cfg)
            params, opt_state, _ = O.adamw_update(
                g, opt_state, params, opt, jnp.float32(opt.lr))
            return params, opt_state, loss

        for i in range(150):
            rgb, labels = stream.batch(i, 32)
            params, opt_state, _ = step(
                params, opt_state, jnp.asarray(rgb), jnp.asarray(labels))
        accs = []
        for j in range(4):
            rgb, labels = stream.batch(50_000 + j, 32)
            _, acc = vit_loss(params, jnp.asarray(rgb), jnp.asarray(labels), cfg)
            accs.append(float(acc))
        assert sum(accs) / len(accs) > 0.5   # chance = 0.25

    def test_frontend_weights_receive_gradients(self):
        cfg = ViTConfig(frontend=_fcfg(), n_layers=1, d_model=32, n_heads=2, d_ff=64)
        params = init_vit(KEY, cfg)
        rgb = jax.random.uniform(KEY, (4, 64, 64, 3))
        labels = jnp.array([0, 1, 2, 3])
        g = jax.grad(lambda p: vit_loss(p, rgb, labels, cfg)[0])(params)
        assert float(jnp.abs(g["ip2"]["a_rgb"]).max()) > 0.0


class TestServing:
    def test_saccade_loop_masks_persist(self):
        cfg = ViTConfig(frontend=_fcfg(), n_layers=1, d_model=32, n_heads=2, d_ff=64)
        params = init_vit(KEY, cfg)
        stream = SceneStream(image=64)
        mask = None
        for t in range(3):
            rgb, _ = stream.batch(t, 4)
            rgb = jnp.asarray(rgb)
            logits = vit_forward(params, rgb, cfg, mask=mask)
            patches = c.extract_patches(c.mosaic(rgb), 16, 16)
            mask = c.topk_patch_mask(c.patch_energy(patches), 0.25)
            assert logits.shape == (4, 4)
            assert int(mask.sum()) == 4 * 4   # 25% of 16 patches x batch 4

    def test_closed_saccade_loop_fully_compact(self):
        """Frame t's selection comes from frame t-1's backend attention,
        end to end on the compact path: static shapes, exactly-k indices,
        and no dense (P, M) feature grid anywhere in the jitted step."""
        from repro.serve.serve_step import make_bootstrap_indices, make_saccade_step

        cfg = ViTConfig(frontend=_fcfg(), n_layers=1, d_model=32, n_heads=2, d_ff=64)
        params = init_vit(KEY, cfg)
        stream = SceneStream(image=64)
        bootstrap = jax.jit(make_bootstrap_indices(cfg))
        step = jax.jit(make_saccade_step(cfg))
        k = cfg.frontend.n_active

        indices = None
        selections = []
        for t in range(4):
            rgb, _ = stream.batch(t, 4)
            rgb = jnp.asarray(rgb)
            if indices is None:
                indices = bootstrap(params, rgb)
            logits, indices, aux = step(params, rgb, indices)
            assert logits.shape == (4, 4)
            assert indices.shape == (4, k) and indices.dtype == jnp.int32
            # exactly k distinct patches per element (top-k of scattered
            # attention can't repeat an index)
            assert all(len(set(row)) == k for row in np.asarray(indices))
            assert bool(aux["valid"].all())
            selections.append({tuple(sorted(r)) for r in np.asarray(indices)})
        # the gaze must be able to move: a frozen selection means the
        # attention/energy scores can never outrank the bootstrap set
        assert any(selections[i] != selections[i + 1] for i in range(3))

    def test_multiframe_saccade_matches_dense_oracle(self):
        """T=4 frames of the compact closed loop vs a dense-path oracle:
        for the same selection, frame-for-frame, the logits must agree AND
        the NEXT selection must agree — i.e. the whole trajectory of the
        serving path is reproducible from the dense (training) path."""
        from repro.core.frontend import sensor_patches
        from repro.serve.serve_step import (
            make_bootstrap_indices, make_saccade_step, saccade_scores,
        )

        cfg = ViTConfig(frontend=_fcfg(), n_layers=2, d_model=64, n_heads=4,
                        d_ff=128)
        params = init_vit(KEY, cfg)
        stream = SceneStream(image=64)
        step = jax.jit(make_saccade_step(cfg))
        k, P = cfg.frontend.n_active, cfg.frontend.n_patches

        indices = make_bootstrap_indices(cfg)(
            params, jnp.asarray(stream.batch(0, 4)[0]))
        for t in range(4):
            rgb = jnp.asarray(stream.batch(t, 4)[0])
            logits_c, next_c, _ = step(params, rgb, indices)

            # dense oracle for the same selection: masked grid forward,
            # saliency from the dense attention, energy straight from the
            # sensor — then the SAME scoring policy
            mask = c.mask_from_indices(indices, P)
            logits_d, aux_d = vit_forward(params, rgb, cfg, mask=mask,
                                          return_aux=True)
            patches, _ = sensor_patches(params["ip2"], rgb, cfg.frontend)
            oracle_aux = {
                "saliency": aux_d["saliency"],
                "indices": indices,
                "valid": jnp.ones(indices.shape, bool),
                "energy": c.patch_energy(patches),
            }
            next_d = c.topk_patch_indices(saccade_scores(oracle_aux, 0.1), k)

            np.testing.assert_allclose(
                np.asarray(logits_c), np.asarray(logits_d), atol=2e-5,
                err_msg=f"frame {t}: dense/compact logits diverged")
            np.testing.assert_array_equal(
                np.asarray(next_c), np.asarray(next_d),
                err_msg=f"frame {t}: dense/compact next selection diverged")
            indices = next_c


@pytest.mark.skipif(
    not os.path.exists("results/dryrun.json"), reason="dry-run results absent"
)
class TestDryRunGate:
    def test_all_cells_compiled(self):
        with open("results/dryrun.json") as f:
            r = json.load(f)
        failed = {k: v["error"] for k, v in r.items() if "error" in v}
        assert not failed, failed
        # every assigned cell present on both meshes (32 = 40 minus the
        # documented long_500k skips for full-attention archs)
        single = [k for k in r if k.endswith("/single")]
        multi = [k for k in r if k.endswith("/multi")]
        assert len(single) >= 32 and len(multi) >= 32

    def test_collective_schedule_present(self):
        with open("results/dryrun.json") as f:
            r = json.load(f)
        cell = r.get("llama3-8b/train_4k/multi") or r.get("llama3-8b/train_4k/single")
        assert cell and cell["full_collectives"].get("all-reduce", 0) > 0
